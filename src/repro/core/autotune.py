"""Kernel parameter generation + selection (paper §III-B code generation).

The paper generates ~150 CUTLASS kernels per dtype over a pruned parameter
space, keeps those that compile and run, benchmarks 64 problem sizes and
selects a per-shape winner. On TPU the "template instantiation" is a Pallas
closure specialization, but the search/selection pipeline is the same — and
as of the template-family refactor it searches three axes, not one:

  variant x tiles x dtype

  1. ``parameter_space(dtype)`` — candidates under the paper's pruning rules
                               (§III-B-1): powers of two, contraction tile
                               tied to the pipeline depth, MXU-aligned
                               tiles. 2-byte dtypes admit wider tiles (the
                               same VMEM budget holds twice the elements).
  2. ``feasible()``          — does the kernel lower (compile-time check),
                               does the working set fit VMEM (dtype-aware
                               byte sizing), is the sublane alignment legal
                               for the dtype, and — for the ``smallk``
                               variant — does padded K fit one tile.
  3. ``score()``             — selection criterion. Two modes:
                               "model": analytical HBM-traffic/MXU-occupancy
                               model (used when the target TPU is absent —
                               this container), "measure": wall-time of the
                               real kernel (used on device; also drives the
                               CPU benchmark figures via the jnp fallback).
  4. ``AutotuneCache``       — per-shape winners, persisted as JSON: the
                               kernel-selection table the runtime consults.
                               Lives in ``repro.api.cache`` as an injectable
                               object (passed per-estimator); this module
                               keeps only the search/selection pipeline.

``select_params`` returns a ``(variant, KernelParams)`` pair. The variant
is implied by the winning tiles (``ops.resolve_variant``: smallk iff K fits
one ``block_k`` tile), so kernel dispatch and selection can never disagree;
the pair makes the chosen template explicit to callers and to the cache.
"""
from __future__ import annotations

import functools
import itertools
import time
import warnings
from typing import Iterable, Optional

import jax
import jax.numpy as jnp

from repro import hw as _hw
from repro.kernels.ops import (PLAN_KINDS, VARIANTS, KernelParams, clamp_params,  # noqa: F401 — VARIANTS re-exported as selection vocabulary
                               init_vmem_bytes, int8_vmem_bytes,
                               lloyd_batched_vmem_bytes,
                               lloyd_ft_vmem_bytes, lloyd_vmem_bytes,
                               pruned_vmem_bytes, sublane_align, _round_up)

# TPU v5e constants — hoisted to repro.hw (shared with roofline/hw.py so the
# two models can't drift); the old names stay importable from here.
MXU_FLOPS = _hw.PEAK_FLOPS_BF16   # 2-byte peak; f32 ~ 1/2
HBM_BW = _hw.HBM_BW               # bytes/s
VMEM_BUDGET = _hw.VMEM_BUDGET     # bytes usable per core

# Kernel kinds sharing the tile-parameter space but with distinct VMEM
# footprints and HBM-traffic profiles (winners must not cross kinds).
# "lloyd_ft" is the one-pass FT kernel: one-pass footprint plus the fused
# dual-checksum scratch and the expected-checksum output blocks of the
# protected update epilogue; its model charges the checksum FLOPs/traffic.
# "batched" is the many-problem one-pass kernel: B problems per launch,
# problem axis outermost in the grid, padded K always a single centroid
# tile (so block_k is not a search axis and winners are additionally keyed
# by the B bucket — a B=4 launch and a B=1024 launch amortize dispatch and
# pipeline ramp-up very differently at the same per-problem shape).
# "pruned" is the bounds-carrying one-pass kernel: surviving tiles pay the
# one-pass cost, skipped tiles pay nothing, so its model takes an assumed
# prune rate and its measure mode runs on *clustered* data (uniform data
# never prunes, which would rank every candidate on full-compute time).
#
# The vocabulary itself lives in ``ops.PLAN_KINDS`` (the dispatch table of
# ``ops.kernel_plan``) so the cache-schema kinds, the contract checker and
# the selection pipeline extend from a single point of change.
KINDS = PLAN_KINDS

# Kinds that run the one-pass (fused-update) kernel family.
_LLOYD_KINDS = ("lloyd", "lloyd_ft", "pruned")


def shard_shape(m: int, k: int, f: int,
                data_shards: int) -> tuple[int, int, int]:
    """The per-shard problem shape a data-sharded fit autotunes for.

    A distributed fit's winner lookups key by the *local*
    ``(rows/shard, K, F)`` problem: tile selection sees the per-device
    GEMM, not the global one, and a winner tuned for the global M would
    pick block_m tiles the shard can't fill. Keeping the division here —
    rather than inline at call sites — makes the contract explicit and
    validated: rows must divide evenly, and a mesh rescale re-keys every
    lookup at the *new* shard shape (``DistributedKMeans`` rebuilds its
    step cache against this function after ``plan_rescale``).
    """
    if data_shards < 1:
        raise ValueError(f"data_shards must be >= 1, got {data_shards}")
    if m % data_shards:
        raise ValueError(
            f"rows m={m} do not divide evenly over {data_shards} data "
            f"shards; pad the input or pick a mesh whose row parallelism "
            f"divides M")
    return (m // data_shards, k, f)


def parameter_space(dtype=jnp.float32) -> list[KernelParams]:
    """Pruned candidate grid (paper rules: powers of 2; Warp.K=Threadblock.K
    maps to a single contraction tile; thread tile fixed by MXU shape).

    The grid is per-dtype, like the paper's per-dtype generator: 2-byte
    dtypes (bf16/fp16) halve every tile's bytes, so the same VMEM budget
    admits one more power of two on the sample and contraction axes.
    """
    block_ms = [64, 128, 256, 512, 1024]
    block_ks = [128, 256, 512]
    block_fs = [128, 256, 512, 1024]
    if jnp.dtype(dtype).itemsize <= 2:
        block_ms = block_ms + [2048]
        block_fs = block_fs + [2048]
    out = []
    for bm, bk, bf in itertools.product(block_ms, block_ks, block_fs):
        out.append(KernelParams(block_m=bm, block_k=bk, block_f=bf))
    return out


def feasible(p: KernelParams, dtype=jnp.float32, *, kind: str = "assign",
             shape: Optional[tuple[int, int, int]] = None,
             variant: str = "generic") -> bool:
    """VMEM fit + alignment. The lowering check happens once in tests
    (tests/test_autotune.py) — analogous to the paper's compile-and-run
    filter; here we apply the cheap structural conditions.

    Dtype-aware: the sublane alignment of ``block_m`` is 16 for 2-byte
    dtypes (vs 8 for f32) and the working-set bytes scale with the input
    itemsize. The ``smallk`` variant additionally needs the problem shape
    to check that padded K fits a single ``block_k`` tile; the one-pass
    Lloyd kernel keeps the whole stashed X row tile and its (K, F)
    partial-sum output block resident, so its VMEM model also depends on
    ``shape=(m, k, f)``.
    """
    if p.block_m % sublane_align(dtype) or p.block_k % 128 or p.block_f % 128:
        return False
    if kind == "batched":
        # one problem's tiles resident at a time; padded K is the single
        # centroid tile by construction, so block_k never enters
        if shape is None:
            return False
        _, k, f = shape
        return lloyd_batched_vmem_bytes(p, k, f, dtype) <= VMEM_BUDGET
    if variant == "smallk":
        if kind == "lloyd_ft":
            # FT templates keep the generic grid (checksum scratch is
            # already VMEM-resident; no revisited-output stream to save)
            return False
        if shape is None:
            return False
        _, k, _ = shape
        if _round_up(k, p.block_k) != p.block_k:
            return False
    if kind in _LLOYD_KINDS and shape is not None:
        _, k, f = shape
        vmem = {"lloyd_ft": lloyd_ft_vmem_bytes,
                "pruned": pruned_vmem_bytes}.get(kind, lloyd_vmem_bytes)
        return vmem(p, k, f, dtype) <= VMEM_BUDGET
    if kind == "int8":
        # fixed-dtype template: 1-byte tiles, f32 scale/norm vectors and
        # the int32 accumulator — its own exact byte model
        return int8_vmem_bytes(p) <= VMEM_BUDGET
    if kind == "init":
        # fused k-means++ round: the d² and tile-sum blocks put block_m
        # on a lane-tiled axis, so it needs the 128 alignment; features
        # are fully resident, so feasibility depends on F
        if shape is None or p.block_m % 128:
            return False
        _, _, f = shape
        return init_vmem_bytes(p, f) <= VMEM_BUDGET
    return p.vmem_bytes(dtype) <= VMEM_BUDGET


def iteration_traffic(m: int, k: int, f: int, p: KernelParams, *,
                      pipeline: str = "one_pass",
                      dtype=jnp.float32) -> dict[str, int]:
    """Per-Lloyd-iteration HBM byte traffic, itemized by source.

    ``pipeline`` names the iteration structure (distinct from the kernel
    ``kind`` vocabulary used by selection):

    ``"two_pass"``: the seed pipeline — fused assignment kernel, then
    a separate centroid-update pass that re-reads all of X, plus the
    per-iteration re-pad/re-norm of X the seed estimator performed inside
    every kernel call.

    ``"one_pass"``: the fused ``lloyd_step`` kernel — X enters the
    kernel once per centroid tile and is never read again; the update
    costs only the per-row-tile partial sums/counts round trip of the
    tree-reduction. Padding and norms are amortized by the per-fit
    :class:`~repro.kernels.ops.DataPlan` (zero per-iteration bytes).

    Byte sizing is split by stream: X/C reads move the input dtype
    (f32/bf16/fp16), while distances, partial sums, counts and the final
    centroids are always f32 and the argmin is always i32 — the previous
    model charged the input itemsize for those f32 streams too, skewing
    every non-f32 estimate.
    """
    if pipeline not in ("one_pass", "two_pass"):
        raise ValueError(f"pipeline must be 'one_pass' or 'two_pass', "
                         f"got {pipeline!r}")
    p = clamp_params(m, k, f, p, dtype)
    b = jnp.dtype(dtype).itemsize
    mp = _round_up(m, p.block_m)
    kp = _round_up(k, p.block_k)
    fp = _round_up(f, p.block_f)
    n_ktiles = kp // p.block_k
    n_mtiles = mp // p.block_m
    t = {
        "x_read": mp * fp * n_ktiles * b,         # once per centroid tile
        "c_read": kp * fp * n_mtiles * b,         # once per sample tile
        "assign_out": mp * (4 + 4),               # min-dist f32 + argmin i32
    }
    if pipeline == "two_pass":
        # re-pad write + 2x re-read in the input dtype; row norms are f32
        t["prep"] = (mp * fp + 2 * m * f) * b
        t["update_x_reread"] = m * f * b + m * 4  # second pass over X + labels
        t["update_out"] = (k * f + k) * 4         # sums/counts are f32
    else:
        t["prep"] = 0
        t["update_x_reread"] = 0
        # f32 partial blocks written by the kernel, then read + collapsed by
        # the tree-reduction into the (K, F) sums / (K,) counts
        partials = n_mtiles * (kp * fp + kp) * 4
        t["update_out"] = 2 * partials + (k * f + k) * 4
    t["total"] = sum(t.values())
    return t


def model_score(m: int, k: int, f: int, p: KernelParams,
                dtype=jnp.float32, kind: str = "assign",
                variant: str = "generic", batch: int = 1,
                prune_rate: float = 0.5) -> float:
    """Analytical time estimate (seconds) for one fused-kernel launch.

    HBM traffic: X is re-read once per centroid tile, C once per sample
    tile (the paper's §V-A-6 observation that balanced tiles minimize data
    movement); compute: 2 M K F MACs on the MXU at the dtype's peak rate.
    The kernel is pipelined, so time ~ max(compute, memory) + epilogue.
    The ``lloyd`` kind adds the partial-sum output traffic and the one-hot
    update GEMM of the fused epilogue.

    The variant axis shows up in the min/argmin output stream: the generic
    template initializes the revisited (bm, 1) blocks and re-reads/rewrites
    them on every centroid tile (2 x n_ktiles visits), where the ``smallk``
    template writes each block exactly once — so whenever K fits a single
    centroid tile the small-K variant strictly wins the model, which is
    what routes it through selection.

    The ``batched`` kind is B independent problems through the smallk-style
    one-pass grid: per-problem cost is the smallk ``lloyd`` estimate and
    the launch is its B-fold — dispatch amortization is exactly what the
    model cannot see, which is why batched winners are *measured* on real
    hardware and the B bucket is part of the cache key.

    The ``pruned`` kind discounts the distance GEMM (MACs and the
    per-centroid-tile X re-reads) by ``prune_rate`` — the assumed fraction
    of (row tile, centroid tile) cells the triangle-inequality filter
    skips in steady state; the fused update epilogue, the partial-sum
    round trip and the output streams are unconditional and stay at full
    cost. The default 0.5 is deliberately conservative (late iterations on
    clustered data reach far higher); the real rate is data- and
    alignment-dependent, which is why pruned winners prefer measure mode
    on clustered inputs.

    The ``int8`` kind scores like ``assign`` with 1-byte x/c streams and
    the int8 MXU peak (``hw.PEAK_FLOPS_INT8``): callers pass
    ``dtype=jnp.int8`` and the itemsize/peak lookups do the rest. The f32
    scale vectors and centroid norms are O(M + K) streams — noise next to
    the O(M F) tiles — and are not charged.

    The ``serve`` kind is the ``assign`` score plus the fixed per-launch
    dispatch cost (``hw.DISPATCH_OVERHEAD_S``): an online predict cell is
    one assignment-kernel launch at a bucket shape, and at serving sizes
    the launch cost is a first-order term, not noise.
    """
    if kind == "batched":
        return batch * model_score(m, k, f, p, dtype=dtype, kind="lloyd",
                                   variant="smallk")
    if kind == "init":
        # one fused k-means++ D² round is memory-bound: X streams once
        # against a single centroid row (F MACs per row — VPU work,
        # nowhere near the MXU), while the norm/d² vectors round-trip.
        # Tile size matters only through row padding, which is exactly
        # what this captures; K is not an axis of the round at all.
        bn = max(128, clamp_params(m, k, f, p, dtype).block_m)
        mp = _round_up(m, bn)
        fp = _round_up(f, 128)
        hbm_bytes = (mp * fp + 4 * mp) * 4     # x tile + xn/d2-in/out/ts
        # per-grid-step issue cost breaks the tie between tile sizes that
        # pad M equally — bigger tiles amortize it, like real hardware
        return float(batch * (hbm_bytes / HBM_BW + (mp // bn) * 1e-7))
    if kind == "serve":
        # one AOT predict-cell launch: the assignment kernel at the bucket
        # shape plus the fixed per-launch dispatch cost. The dispatch term
        # is what micro-batching amortizes — summing these scores over a
        # request-size distribution is how the ladder planner trades
        # padding waste against launch count (repro.serve.tuning).
        return _hw.DISPATCH_OVERHEAD_S + model_score(
            m, k, f, p, dtype=dtype, kind="assign", variant=variant)
    p = clamp_params(m, k, f, p, dtype)
    bytes_per = jnp.dtype(dtype).itemsize
    mp = -(-m // p.block_m) * p.block_m
    kp = -(-k // p.block_k) * p.block_k
    fp = -(-f // p.block_f) * p.block_f
    n_ktiles = kp // p.block_k
    x_reads = mp * fp * n_ktiles
    c_reads = kp * fp * (mp // p.block_m)
    hbm_bytes = (x_reads + c_reads) * bytes_per
    macs = mp * kp * fp
    if kind in _LLOYD_KINDS:
        # f32 partial sums/counts blocks out + tree-reduction round trip
        partials = (mp // p.block_m) * (kp * fp + kp) * 4
        hbm_bytes += 2 * partials
        macs += mp * kp * fp          # one-hot scatter GEMM in the epilogue
    if kind == "pruned":
        # skipped cells pay neither the distance MACs nor the per-centroid-
        # tile X re-read; everything else (update epilogue, partials,
        # output streams) is unconditional. Bounds traffic: ub+assign rows
        # in/out, drift-sized centroid snapshot, per-cell tmin/skip words.
        skipped = min(max(prune_rate, 0.0), 1.0)
        hbm_bytes -= skipped * x_reads * bytes_per
        macs -= skipped * mp * kp * fp
        hbm_bytes += 2 * mp * 8 + kp * fp * 4 \
            + 3 * (mp // p.block_m) * (kp // p.block_k) * 4
    if kind == "lloyd_ft":
        # dual-checksum encodings fused into the tile loop: ~2*(bm+bk)*bf
        # MACs per (m, k, f) grid step -> 2*M*K*F*(1/bm + 1/bk) overall
        # (the paper's ~1.2% at (256, 128) tiles), plus the update
        # epilogue's two (bm, fp) encoding products per row tile and the
        # expected-checksum blocks' write + reduce-read round trip
        macs += 2.0 * mp * kp * fp * (1.0 / p.block_m + 1.0 / p.block_k)
        macs += 2 * mp * fp
        hbm_bytes += 2 * (mp // p.block_m) * (2 * fp + 2) * 4
    hbm = hbm_bytes / HBM_BW
    peak = _hw.peak_flops(dtype)
    # MXU efficiency falls off for tiles thinner than the 128x128 systolic
    # array and for padded remainders.
    util = min(p.block_k / 128.0, 1.0) * min(p.block_m / 128.0, 1.0)
    util *= (m / mp) * (k / kp) * (f / fp)
    compute = 2.0 * macs / (peak * max(util, 1e-3))
    # VMEM-resident reduce over the (bm, bk) accumulator — always f32,
    # whatever the input dtype
    epilogue = mp * kp * 4 / (HBM_BW * 16)
    # min/argmin stream: the generic template initializes the revisited
    # (bm, 1) output blocks and re-reads/rewrites them on every centroid
    # tile (2 x n_ktiles visits); smallk writes each block exactly once.
    # This round trip happens at epilogue time, serialized behind the tile
    # pipeline, so it adds outside the max() — which is also what makes the
    # small-K variant strictly outrank the generic one whenever K fits a
    # single centroid tile, even for compute-bound shapes.
    out_visits = 1 if variant == "smallk" else 2 * n_ktiles
    out_stream = out_visits * mp * 8 / HBM_BW
    return float(max(hbm, compute) + epilogue + out_stream)


def measure_score(m: int, k: int, f: int, p: KernelParams, *, iters: int = 3,
                  dtype=jnp.float32, kind: str = "assign",
                  variant: Optional[str] = None, batch: int = 1,
                  interpret: Optional[bool] = None) -> float:
    """Median wall-time of the real kernel on the current backend (seconds).

    ``interpret=None`` resolves to the real compiled kernel whenever a TPU
    backend is present; the Pallas interpreter is only an *explicit*
    fallback for kernel-path smoke timing off-device (it measures the
    interpreter, not the kernel — a number that must never be presented as
    hardware performance, which is why ``benchmarks/check_regression``
    refuses interpret-mode rungs as guards).

    Inputs are seeded-random (all-ones invited constant folding), the
    candidate pipeline is compiled exactly once up front (naively repeating
    ``fused_assign`` re-ran its eager padding prologue every call), and
    every timed call is individually ``block_until_ready`` so candidates
    are ranked on real kernel time, not dispatch pipelining. The
    ``batched`` kind times one B-problem launch of the batched kernel —
    the whole point of its measure mode, since dispatch amortization is
    invisible to the analytical model.

    The ``pruned`` kind runs two iterations on *clustered* synthetic data
    (cluster-contiguous rows, centroid order aligned with row order): the
    first call seeds the bounds state (unpruned by construction), the
    timed calls run warmed — the steady state a long fit spends almost all
    its iterations in. Uniform data never prunes, so measuring on it would
    rank every candidate on full-compute time and the pruned kind would
    never beat the plain one-pass winner.

    The ``int8`` kind feeds float data through the full quantize +
    int8-template path (``fused_assign_int8``), so the timed number
    includes the per-call centroid quantization the real iteration pays.

    The ``serve`` kind times the assignment kernel at the bucket shape —
    the same pipeline as ``assign``. The per-launch dispatch constant the
    serve *model* adds is shape-independent, so measured rankings agree
    with modeled ones up to that constant."""
    from repro.kernels.ops import (fused_assign, fused_assign_int8,
                                   fused_lloyd, fused_lloyd_batched,
                                   fused_lloyd_ft, fused_lloyd_pruned,
                                   init_bounds, on_tpu)
    if interpret is None:
        interpret = not on_tpu()
    if kind == "init":
        # time one fused D² round at the candidate's row tile: the round
        # dominates the seeding loop (selection is O(T + bn) glue), and
        # batch enters as the B problems of one launch
        from repro.kernels.kmeanspp_init import (clamp_init_block,
                                                 kmeanspp_round)
        bn = clamp_init_block(m, clamp_params(m, k, f, p, dtype).block_m)
        np_ = _round_up(m, bn)
        fp_ = _round_up(f, 128)
        kx, kc = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(kx, (batch, np_, fp_), jnp.float32)
        xn = jnp.sum(x * x, axis=2)
        c = jax.random.normal(kc, (batch, 1, fp_), jnp.float32)
        d2 = xn + 1.0
        fn_i = jax.jit(functools.partial(kmeanspp_round, block_n=bn,
                                         interpret=interpret))
        jax.block_until_ready(fn_i(x, xn, c, d2))
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn_i(x, xn, c, d2))
            times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]
    kx, kc = jax.random.split(jax.random.PRNGKey(0))
    if kind == "batched":
        x = jax.random.normal(kx, (batch, m, f), dtype)
        c = jax.random.normal(kc, (batch, k, f), dtype)
    elif kind == "pruned":
        x, c = _clustered_data(m, k, f, dtype)
    elif kind == "int8":
        # the template quantizes internally; feed it float data
        x = jax.random.normal(kx, (m, f), jnp.float32)
        c = jax.random.normal(kc, (k, f), jnp.float32)
    else:
        x = jax.random.normal(kx, (m, f), dtype)
        c = jax.random.normal(kc, (k, f), dtype)
    p = clamp_params(m, k, f, p, jnp.int8 if kind == "int8" else dtype)
    if kind == "batched":    # smallk-style grid: no variant/block_k axis
        fn = jax.jit(functools.partial(fused_lloyd_batched, params=p,
                                       interpret=interpret))
    elif kind == "lloyd_ft":   # generic-grid template: no variant axis
        fn = jax.jit(functools.partial(fused_lloyd_ft, params=p,
                                       interpret=interpret))
    elif kind == "int8":
        fn = jax.jit(functools.partial(fused_assign_int8, params=p,
                                       variant=variant, interpret=interpret))
    elif kind == "pruned":
        step_p = jax.jit(functools.partial(fused_lloyd_pruned, params=p,
                                           variant=variant,
                                           interpret=interpret))
        seeded = step_p(x, c, bounds=init_bounds(m, k, f, p, dtype=dtype))
        bounds = seeded[4]   # iteration 1 of 2: the unpruned seeding pass
        fn = functools.partial(step_p, bounds=bounds)
    else:
        step = fused_lloyd if kind == "lloyd" else fused_assign
        fn = jax.jit(functools.partial(step, params=p, variant=variant,
                                       interpret=interpret))
    jax.block_until_ready(fn(x, c))          # compile outside the timing
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, c))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _clustered_data(m: int, k: int, f: int, dtype) -> tuple:
    """Seeded well-separated Gaussian blobs for the pruned kind's measure
    mode: cluster-contiguous rows assigned round-robin-free (rows of
    cluster j are the contiguous slice j*m/k..(j+1)*m/k) and centroids in
    cluster order, so row tiles and centroid tiles align — the regime tile
    pruning is built for. ``benchmarks/common.clustered_blobs`` is the
    user-facing twin (src must not import from benchmarks/)."""
    kx, kc = jax.random.split(jax.random.PRNGKey(7))
    centers = jax.random.normal(kc, (k, f), jnp.float32) * 8.0
    labels = (jnp.arange(m) * k) // m
    x = centers[labels] + jax.random.normal(kx, (m, f), jnp.float32)
    return x.astype(dtype), centers.astype(dtype)


def select_params(m: int, k: int, f: int, *, mode: str = "model",
                  dtype=jnp.float32, kind: str = "assign",
                  space: Optional[Iterable[KernelParams]] = None,
                  batch: int = 1) -> tuple[str, KernelParams]:
    """Pick the winner for one problem shape and kernel kind.

    Searches variant x tiles for the given dtype and returns the winning
    ``(variant, KernelParams)`` pair. The small-K variant competes whenever
    padded K fits one centroid tile and, by construction of the model,
    outranks the generic template there (no revisited-output machinery).
    The ``batched`` kind searches (block_m, block_f) only — padded K is the
    single centroid tile by construction — and scores one B-problem launch
    (``batch`` enters measure mode directly and the cache key's B bucket).
    """
    from repro.kernels.ops import resolve_variant
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    # Degenerate shapes: a serving layer legitimately sees zero-row
    # requests (the ops layer early-returns before any launch, but a cache
    # lookup may still ask for a selection at M=0). Score the smallest
    # real shape instead of dividing by a zero-row padded extent.
    m, k, f = max(m, 1), max(k, 1), max(f, 1)
    best, best_s = None, float("inf")
    if kind == "init":
        # the fused k-means++ round kernel has one tile axis: block_m.
        # K never enters the round and F is fully resident, so block_k /
        # block_f are not searched (mirroring how 'batched' drops block_k)
        seen = set()
        for p in (space or parameter_space(dtype)):
            if p.block_m in seen:
                continue
            seen.add(p.block_m)
            if not feasible(p, dtype, kind=kind, shape=(m, k, f)):
                continue
            s = (model_score(m, k, f, p, dtype=dtype, kind=kind,
                             batch=batch)
                 if mode == "model"
                 else measure_score(m, k, f, p, dtype=dtype, kind=kind,
                                    batch=batch))
            if s < best_s:
                best, best_s = ("generic", p), s
        if best is None:
            raise ValueError(
                f"no feasible 'init' kernel parameters for shape "
                f"{(m, k, f)}: every candidate's resident (block_m, F) "
                f"sample tile exceeds VMEM (the round kernel keeps all of "
                f"F resident; reduce F or use the vmapped seeding path)")
        return best
    if kind == "batched":
        seen = set()
        for p in (space or parameter_space(dtype)):
            if (p.block_m, p.block_f) in seen:   # block_k is not an axis
                continue
            seen.add((p.block_m, p.block_f))
            if not feasible(p, dtype, kind=kind, shape=(m, k, f)):
                continue
            s = (model_score(m, k, f, p, dtype=dtype, kind=kind, batch=batch)
                 if mode == "model"
                 else measure_score(m, k, f, p, dtype=dtype, kind=kind,
                                    batch=batch))
            if s < best_s:
                best, best_s = ("batched", p), s
        if best is None:
            raise ValueError(
                f"no feasible 'batched' kernel parameters for per-problem "
                f"shape {(m, k, f)}: every candidate's working set exceeds "
                f"VMEM (the batched kernel keeps one problem's stashed X "
                f"row tile and (K, F) partial block resident; shrink the "
                f"problems or run them through the single-problem path)")
        return best
    for p in (space or parameter_space(dtype)):
        # The variant is a function of (K, tiles) — the dispatch rule — so
        # each tile candidate is scored as the template it would actually
        # run (scoring the other variant would benchmark a kernel the
        # runtime can never launch for these tiles). Dispatch sees the
        # *clamped* tiles, so the variant must be derived from them too:
        # clamping can shrink block_k below the K-fit threshold. FT kinds
        # only ship the generic-grid template.
        variant = ("generic" if kind == "lloyd_ft"
                   else resolve_variant(k, clamp_params(m, k, f, p, dtype)))
        if not feasible(p, dtype, kind=kind, shape=(m, k, f),
                        variant=variant):
            continue
        s = (model_score(m, k, f, p, dtype=dtype, kind=kind,
                         variant=variant)
             if mode == "model"
             else measure_score(m, k, f, p, dtype=dtype, kind=kind,
                                variant=variant))
        if s < best_s:
            best, best_s = (variant, p), s
    if best is None:
        hint = (" (the one-pass kernel keeps the stashed X row tile and "
                "its (K, F) partial-sum block VMEM-resident; use a "
                "two-pass backend for this shape)"
                if kind in _LLOYD_KINDS else "")
        raise ValueError(f"no feasible {kind!r} kernel parameters for "
                         f"shape {(m, k, f)}: every candidate's working "
                         f"set exceeds VMEM{hint}")
    return best


# ---------------------------------------------------------------------------
# Winner table: owned by repro.api.cache.AutotuneCache (an injectable object,
# passed per-estimator). The deprecated helpers below delegate to the
# process-default cache for callers not yet migrated.
# ---------------------------------------------------------------------------


def build_table(shapes: Iterable[tuple[int, int, int]], *, mode: str = "model",
                dtype=jnp.float32, path: Optional[str] = None) -> dict:
    """Deprecated: use ``AutotuneCache(path).build(shapes, mode=...)``."""
    warnings.warn("autotune.build_table is deprecated; use "
                  "repro.api.AutotuneCache(path).build(...)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import AutotuneCache, default_cache
    cache = AutotuneCache(path) if path else default_cache()
    return cache.build(shapes, mode=mode, dtype=dtype)


def lookup_params(m: int, k: int, f: int) -> KernelParams:
    """Deprecated: use ``repro.api.AutotuneCache.lookup`` (injectable) or
    ``repro.api.default_cache()`` for the process-wide table."""
    warnings.warn("autotune.lookup_params is deprecated; use "
                  "repro.api.default_cache().lookup(m, k, f)",
                  DeprecationWarning, stacklevel=2)
    from repro.api.cache import default_cache
    return default_cache().lookup(m, k, f)[1]
