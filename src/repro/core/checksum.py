"""Dual-checksum (e1/e2) ABFT encodings with location decoding.

Implements the paper's §IV scheme in pure JAX:

  e1 = [1, 1, ..., 1]      detects an error (non-zero residual)
  e2 = [1, 2, ..., n]      locates it: index = round(r2 / r1)

For a matmul D = X @ Y (X: (m, k), Y: (k, n)):

  column checksums:  C1 = e1(m)^T D = (e1^T X) Y       shape (n,)
                     C2 = e2(m)^T D = (e2^T X) Y       shape (n,)
  row checksums:     R1 = D e1(n)   = X (Y e1)         shape (m,)
                     R2 = D e2(n)   = X (Y e2)         shape (m,)

A single corrupted element D[i, j] += delta produces residuals
  r1_col[j] = delta, r2_col[j] = (i+1) * delta   -> i = r2/r1 - 1
  r1_row[i] = delta, r2_row[i] = (j+1) * delta   -> j = r2/r1 - 1
so the element is corrected in place:  D[i, j] -= delta.

All functions are jit-safe (fixed shapes, lax control flow).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def e1(n: int, dtype=jnp.float32) -> jax.Array:
    """The detection vector [1, 1, ..., 1]."""
    return jnp.ones((n,), dtype=dtype)


def e2(n: int, dtype=jnp.float32) -> jax.Array:
    """The location-encoding vector [1, 2, ..., n]."""
    return jnp.arange(1, n + 1, dtype=dtype)


def encode_cols(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Column checksums of x: (e1^T x, e2^T x), each of shape (x.shape[1],)."""
    w = e2(x.shape[0], x.dtype)
    return jnp.sum(x, axis=0), w @ x


def encode_rows(y: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Row checksums of y: (y e1, y e2), each of shape (y.shape[0],)."""
    w = e2(y.shape[1], y.dtype)
    return jnp.sum(y, axis=1), y @ w


def rounding_eps(input_dtype=jnp.float32, acc_dtype=jnp.float32) -> float:
    """Worst-case unit roundoff of a checksummed accumulation whose *inputs*
    are ``input_dtype`` and whose accumulator is ``acc_dtype``.

    The FT kernels compute their checksums on f32 casts of the resident
    tiles, but the main accumulator they are compared against is built from
    products of the *input* dtype — on backends that round those products
    to input precision (rather than multiplying exactly into f32 the way
    the MXU does for bf16), its rounding floor is the input dtype's eps,
    not f32's. A threshold derived from f32 eps alone then flags clean
    bf16/fp16 tiles as corrupted. Taking ``max(eps_in, eps_acc)`` keeps
    the false-positive rate at the design level for every input dtype;
    injected bit-flips in the campaign range (2^4..2^23) still clear the
    bf16-scaled threshold by orders of magnitude.
    """
    eps_in = float(jnp.finfo(jnp.dtype(input_dtype)).eps) \
        if jnp.issubdtype(jnp.dtype(input_dtype), jnp.floating) else 0.0
    return max(eps_in, float(jnp.finfo(jnp.dtype(acc_dtype)).eps))


def threshold_factor(k: int, input_dtype=jnp.float32,
                     acc_dtype=jnp.float32) -> float:
    """Static (Python-float) part of the detection threshold for a length-k
    contraction: ``16 * sqrt(k) * rounding_eps``. Kernels multiply this by
    their runtime magnitude scale (max |accumulator|); ``16`` keeps the
    false-positive rate negligible (paper §II-A) while exponent and
    high-mantissa bit flips exceed it by many orders of magnitude."""
    return 16.0 * (max(k, 1) ** 0.5) * rounding_eps(input_dtype, acc_dtype)


def default_threshold(k: int, dtype=jnp.float32, scale: float = 1.0,
                      input_dtype=None) -> float:
    """Detection threshold delta for a length-k contraction.

    Rounding error of a k-term dot product is ~ sqrt(k) * eps * |x||y| in
    rms; the checksum residual compounds two such sums, so we take
    ``16 * sqrt(k) * eps * scale`` (scale ~ typical |D| magnitude).
    ``dtype`` is the accumulator dtype; pass ``input_dtype`` when the
    operands are lower precision than the accumulator (bf16/fp16 tiles
    with f32 accumulation) so the threshold tracks the larger rounding
    floor — see :func:`rounding_eps`.
    """
    return threshold_factor(
        k, input_dtype if input_dtype is not None else dtype, dtype) * scale


class ChecksumState(NamedTuple):
    """Checksums carried alongside a product D = X @ Y."""

    col1: jax.Array  # e1^T D, shape (n,)
    col2: jax.Array  # e2^T D, shape (n,)
    row1: jax.Array  # D e1,   shape (m,)
    row2: jax.Array  # D e2,   shape (m,)


def expected_checksums(x: jax.Array, y: jax.Array) -> ChecksumState:
    """Checksums computed from the *inputs* (the ABFT invariant side).

    Cost: O((m + n) * k) — the paper's "CUDA-core" encodings e1^T X, Y e1
    plus the e2 variants, followed by O((m + n) * n) / O((m + n) * m)
    one-row GEMMs (the paper's three extra tensor-core MMAs).
    """
    c1x, c2x = encode_cols(x)   # (k,), (k,)
    r1y, r2y = encode_rows(y)   # (k,), (k,)
    return ChecksumState(
        col1=c1x @ y,
        col2=c2x @ y,
        row1=x @ r1y,
        row2=x @ r2y,
    )


def observed_checksums(d: jax.Array) -> ChecksumState:
    """Checksums computed from the (possibly corrupted) output D."""
    c1, c2 = encode_cols(d)
    r1, r2 = encode_rows(d)
    return ChecksumState(col1=c1, col2=c2, row1=r1, row2=r2)


class Verdict(NamedTuple):
    detected: jax.Array   # bool scalar
    row: jax.Array        # int32 scalar (0 if not detected)
    col: jax.Array        # int32 scalar
    delta: jax.Array      # the error magnitude to subtract


def verify(d: jax.Array, expected: ChecksumState, threshold) -> Verdict:
    """Compare output-derived checksums against input-derived ones.

    Returns the detection verdict with the located (row, col) and delta.
    Under the SEU model (≤1 error per interval) location decoding is exact.
    """
    obs = observed_checksums(d)
    res_col1 = obs.col1 - expected.col1          # (n,)
    res_row1 = obs.row1 - expected.row1          # (m,)
    res_col2 = obs.col2 - expected.col2
    res_row2 = obs.row2 - expected.row2

    # Detection: any column / row residual above threshold.
    col_bad = jnp.abs(res_col1) > threshold
    row_bad = jnp.abs(res_row1) > threshold
    detected = jnp.logical_or(jnp.any(col_bad), jnp.any(row_bad))

    # Location. Primary: the arg-max residual column gives j and delta;
    # the e2/e1 ratio of the *column* residuals gives the row index
    # (paper's location encoding). Cross-check with the row residuals.
    j = jnp.argmax(jnp.abs(res_col1)).astype(jnp.int32)
    delta_col = res_col1[j]
    i_from_ratio = jnp.round(res_col2[j] / jnp.where(delta_col == 0, 1.0, delta_col)) - 1
    # Fall back to the row-residual argmax when column residual is degenerate
    # (e.g. error in a row whose column hit threshold issues).
    i_direct = jnp.argmax(jnp.abs(res_row1)).astype(jnp.int32)
    use_ratio = jnp.abs(delta_col) > threshold
    i = jnp.where(use_ratio, i_from_ratio.astype(jnp.int32), i_direct)
    i = jnp.clip(i, 0, d.shape[0] - 1)
    delta_row = res_row1[i]
    delta = jnp.where(jnp.abs(delta_col) > jnp.abs(delta_row), delta_col, delta_row)
    # If the column residual was degenerate, recover j from the row ratio.
    j_from_ratio = jnp.round(res_row2[i] / jnp.where(delta_row == 0, 1.0, delta_row)) - 1
    j = jnp.where(use_ratio, j, jnp.clip(j_from_ratio.astype(jnp.int32), 0, d.shape[1] - 1))

    zero = jnp.zeros((), jnp.int32)
    return Verdict(
        detected=detected,
        row=jnp.where(detected, i, zero),
        col=jnp.where(detected, j, zero),
        delta=jnp.where(detected, delta, jnp.zeros((), d.dtype)),
    )


def correct(d: jax.Array, verdict: Verdict) -> jax.Array:
    """Subtract the located delta (no-op when nothing was detected)."""
    fixed = d.at[verdict.row, verdict.col].add(-verdict.delta)
    return jnp.where(verdict.detected, fixed, d)
