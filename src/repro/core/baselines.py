"""Fault-tolerance baselines the paper compares against (§I, §V-C).

* ``CheckpointRestartKMeans`` — Taamneh-style: periodically snapshot the
  centroids; a *detected* failure (here: an injected SDC that corrupts the
  assignment step, caught by a post-hoc checksum audit) rolls back to the
  snapshot and recomputes the lost iterations. Cannot catch silent errors
  in-flight; pays recomputation on every hit.
* ``abft_offline`` backend (``FaultPolicy.detect()``) — Wu-style ABFT on
  the materialized product: detects online but corrects by locating on the
  full D, with the extra HBM round trip the paper's fused scheme eliminates.
* cuML-analogue — the ``gemm_fused`` backend (XLA-fused, fixed parameters,
  ``FaultPolicy.off()``), used as the performance baseline in benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import get_backend
from repro.core.fault import FaultConfig, inject
from repro.core.kmeans import (KMeansConfig, KMeansResult, centroid_update,
                               init_kmeanspp, init_random)


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    interval: int = 5          # snapshot every N iterations


class CheckpointRestartKMeans:
    """K-means protected only by checkpoint/restart (the paper's [31]).

    The injected error corrupts the *centroid state* (a compute SDC that
    escaped into the iteration output). Detection is emulated by an audit
    comparing against a shadow step — in real deployments this is a crash
    or a divergence watchdog; either way, recovery = rollback + recompute,
    which is what this baseline measures.
    """

    def __init__(self, cfg: KMeansConfig, policy: CheckpointPolicy = CheckpointPolicy()):
        self.cfg = cfg
        self.policy = policy
        backend = get_backend("gemm_fused")

        def clean_step(x, centroids):
            am, md, _ = backend(x, centroids)
            new_c, counts = centroid_update(x, am, cfg.k, centroids,
                                            use_dmr=False)
            return new_c, am, jnp.sum(md), jnp.sqrt(jnp.sum((new_c - centroids) ** 2))

        self._step = jax.jit(clean_step)

    def fit(self, x: jax.Array, *, fault: Optional[FaultConfig] = None,
            centroids: Optional[jax.Array] = None,
            max_rollbacks: int = 50) -> tuple[KMeansResult, dict]:
        """max_rollbacks: at sustained error rates >= 1/iteration the
        rollback loop cannot make progress (the scheme's fundamental
        limitation vs online ABFT — paper §I); we give up and flag it."""
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        if centroids is None:
            key, sub = jax.random.split(key)
            fn = init_kmeanspp if cfg.init == "kmeans++" else init_random
            centroids = fn(sub, x, cfg.k)

        rng = np.random.default_rng(cfg.seed + 7)
        snapshot = centroids
        snapshot_iter = 0
        stats = {"rollbacks": 0, "wasted_iterations": 0, "checkpoints": 0,
                 "gave_up": False}
        am = jnp.zeros((x.shape[0],), jnp.int32)
        inertia = jnp.asarray(jnp.inf)

        it = 0
        while it < cfg.max_iters:
            new_c, am, inertia, shift = self._step(x, centroids)

            corrupted = fault is not None and fault.enabled() and \
                rng.uniform() < min(fault.rate, 1.0)
            if corrupted:
                key, sub = jax.random.split(key)
                new_c = inject(sub, new_c, fault)
                # Audit detects the corruption -> rollback + recompute.
                stats["rollbacks"] += 1
                stats["wasted_iterations"] += it - snapshot_iter + 1
                centroids = snapshot
                it = snapshot_iter
                if stats["rollbacks"] >= max_rollbacks:
                    stats["gave_up"] = True   # livelock: rate >= 1/iter
                    break
                continue

            centroids = new_c
            it += 1
            if it % self.policy.interval == 0:
                snapshot = centroids
                snapshot_iter = it
                stats["checkpoints"] += 1
            # legacy two-pass baseline: the per-iteration host-driven loop
            # is the measured artifact, not a hot path to optimize
            if float(shift) < cfg.tol:  # analysis: allow=host-sync
                break

        return KMeansResult(centroids, am, inertia, it,
                            jnp.asarray(stats["rollbacks"], jnp.int32)), stats
