"""Dual Modular Redundancy for memory-bound reductions (paper §I / §IV).

The paper's observation: the centroid-update phase is memory-bound — the
latency of streaming the samples dwarfs the arithmetic, so *duplicating
every arithmetic instruction* (DMR) costs <1 %. On TPU the same holds: the
update is an O(M·N) segment-sum limited by HBM bandwidth.

XLA would CSE two identical computations, silently removing the redundancy.
We route the replica through ``jax.lax.optimization_barrier`` so the
compiled program really computes twice, then compare.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def dmr(fn: Callable, *args, atol: float = 0.0):
    """Run fn twice (CSE-proof) and return (result, mismatch_flag).

    mismatch_flag is True when any leaf differs by more than atol —
    the caller decides the recovery policy (recompute / restart). For
    bitwise-deterministic ops atol=0 detects any SDC in either replica.
    """
    primary = fn(*args)
    shadow_args = jax.lax.optimization_barrier(args)
    replica = fn(*shadow_args)

    leaves_p = jax.tree_util.tree_leaves(primary)
    leaves_r = jax.tree_util.tree_leaves(replica)
    bad = jnp.zeros((), jnp.bool_)
    for a, b in zip(leaves_p, leaves_r):
        if jnp.issubdtype(a.dtype, jnp.floating):
            bad = jnp.logical_or(bad, jnp.any(jnp.abs(a - b) > atol))
        else:
            bad = jnp.logical_or(bad, jnp.any(a != b))
    return primary, bad


def dmr_with_retry(fn: Callable, *args, atol: float = 0.0, max_retries: int = 1):
    """DMR + one recomputation on mismatch (triple-vote fallback).

    On mismatch, computes a third replica and majority-votes elementwise.
    Cheap because the protected ops are memory-bound; matches the paper's
    "recompute after detection" policy for the update phase.
    """
    primary = fn(*args)
    shadow_args = jax.lax.optimization_barrier(args)
    replica = fn(*shadow_args)
    third_args = jax.lax.optimization_barrier(shadow_args)
    third = fn(*third_args)

    def vote(a, b, c):
        ab = a == b if not jnp.issubdtype(a.dtype, jnp.floating) else jnp.abs(a - b) <= atol
        return jnp.where(ab, a, c)

    voted = jax.tree_util.tree_map(vote, primary, replica, third)
    bad = jnp.zeros((), jnp.bool_)
    for a, b in zip(jax.tree_util.tree_leaves(primary), jax.tree_util.tree_leaves(replica)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            bad = jnp.logical_or(bad, jnp.any(jnp.abs(a - b) > atol))
        else:
            bad = jnp.logical_or(bad, jnp.any(a != b))
    return voted, bad
