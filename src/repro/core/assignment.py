"""Cluster-assignment strategies — the paper's stepwise ladder (§III-A).

Each strategy maps (x (M, F), c (K, F)) -> (assign (M,) int32, extra):

  naive        the paper's "basic implementation": per-sample loop over all
               centroids, elementwise distances (no GEMM). O(M K F) scalar
               work and O(M K F) intermediate traffic.
  gemm         paper V1: distance via GEMM, *materialized* D (M, K) in HBM,
               separate argmin pass (two kernels, extra round trip).
  gemm_fused   paper V2/V3 analogue on XLA: one jit so XLA fuses the GEMM
               epilogue with the reduction (cuML-analogue baseline).
  fused        paper V4/V5: the Pallas fused kernel (MXU + in-VMEM argmin).
  fused_ft     §IV: fused kernel + dual-checksum ABFT online correction.
  abft_offline Wu-et-al-style baseline: checksummed GEMM *without* fusion —
               detection happens on the materialized product (the scheme the
               paper argues breaks down post-Ampere; here it demonstrates
               the fusion win, not the register-reuse mechanics).

Strategies return a second element: detected-error count (0 where N/A).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import checksum
from repro.core.ft_gemm import ft_matmul
from repro.kernels import ops, ref


def _zero():
    return jnp.zeros((), jnp.int32)


@jax.jit
def assign_naive(x: jax.Array, c: jax.Array):
    # One "thread" per sample; centroids broadcast — no GEMM, pure VPU.
    # Batched over samples in chunks to bound the (M, K, F) intermediate.
    def per_sample(xi):
        d = jnp.sum((xi[None, :] - c) ** 2, axis=1)
        return jnp.argmin(d).astype(jnp.int32), jnp.min(d)
    am, md = jax.lax.map(per_sample, x, batch_size=1024)
    return am, md, _zero()


@jax.jit
def assign_gemm(x: jax.Array, c: jax.Array):
    # Materialize D, then reduce in a second pass. optimization_barrier
    # models the paper's separate-kernel round trip (prevents XLA from
    # fusing the argmin into the GEMM loop).
    d = ref.distance_matrix(x, c)
    d = jax.lax.optimization_barrier(d)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1), _zero()


@jax.jit
def assign_gemm_fused(x: jax.Array, c: jax.Array):
    d = ref.distance_matrix(x, c)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1), _zero()


def assign_fused(x: jax.Array, c: jax.Array, params=None):
    am, md = ops.fused_assign(x, c, params)
    return am, md + jnp.sum(x * x, axis=1), _zero()


def assign_fused_ft(x: jax.Array, c: jax.Array, params=None,
                    inj: Optional[jax.Array] = None):
    am, md, det = ops.fused_assign_ft(x, c, params, inj=inj)
    return am, md + jnp.sum(x * x, axis=1), det


@jax.jit
def assign_abft_offline(x: jax.Array, c: jax.Array):
    cross, detected = ft_matmul(x, c.T)
    d = (jnp.sum(x * x, axis=1, keepdims=True)
         + jnp.sum(c * c, axis=1)[None, :] - 2.0 * cross)
    return (jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1),
            detected.astype(jnp.int32))


STRATEGIES: dict[str, Callable] = {
    "naive": assign_naive,
    "gemm": assign_gemm,
    "gemm_fused": assign_gemm_fused,
    "fused": assign_fused,
    "fused_ft": assign_fused_ft,
    "abft_offline": assign_abft_offline,
}
