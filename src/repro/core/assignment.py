"""Cluster-assignment backends — the paper's stepwise ladder (§III-A).

Each implementation maps (x (M, F), c (K, F)) ->
(assign (M,) int32, true squared distance (M,), detected errors):

  naive        the paper's "basic implementation": per-sample loop over all
               centroids, elementwise distances (no GEMM). O(M K F) scalar
               work and O(M K F) intermediate traffic.
  gemm         paper V1: distance via GEMM, *materialized* D (M, K) in HBM,
               separate argmin pass (two kernels, extra round trip).
  gemm_fused   paper V2/V3 analogue on XLA: one jit so XLA fuses the GEMM
               epilogue with the reduction (cuML-analogue baseline).
  fused        paper V4/V5: the Pallas fused kernel (MXU + in-VMEM argmin).
  int8         quantized distance template, one dtype notch past the
               paper's fp16 floor: per-row symmetric int8 quantization of
               X and C, i8 x i8 -> i32 MXU tiles, f32 scale correction +
               exact norm terms in the epilogue. Bit-exact argmin vs the
               f32 backends on quantization-safe data, error-bounded on
               floats; accepts a per-fit ``ops.QuantPlan``.
  int8_xla     XLA analogue of the int8 template (f32-carrier GEMM over
               the same quantized integers; non-TPU fast path).
  fused_ft     §IV: fused kernel + dual-checksum ABFT online correction.
  abft_offline Wu-et-al-style baseline: checksummed GEMM *without* fusion —
               detection happens on the materialized product (the scheme the
               paper argues breaks down post-Ampere; here it demonstrates
               the fusion win, not the register-reuse mechanics).
  lloyd        one-pass Lloyd (paper Fig. 4 shape): the Pallas kernel's
               epilogue also accumulates per-cluster sums/counts, so a full
               iteration reads X from HBM once. Extended 5-tuple contract
               (``fuses_update=True``).
  lloyd_xla    XLA analogue of the one-pass kernel (non-TPU fast path).
  lloyd_ft     §IV composed with Fig. 4: the one-pass kernel with the
               dual-checksum ABFT fused around the distance GEMM and the
               checksum-protected update epilogue (verified + recomputed
               in the jitted tree-reduction) — the default ``correct``
               protection path, no longer forfeiting the one-pass speedup.
  lloyd_ft_xla XLA analogue of the one-pass FT backend (non-TPU fast path;
               detection + correction at the XLA level, no in-kernel
               injection surface).
  lloyd_batched     batched one-pass Lloyd: B independent problems stacked
               as (B, N, F) / (B, K, F) run through one kernel launch, the
               problem axis outermost in the grid (``supports_batch=True``;
               every output gains a leading B axis).
  lloyd_batched_xla XLA analogue of the batched kernel (batched
               contractions; non-TPU fast path).
  lloyd_pruned one-pass Lloyd with tile-granular triangle-inequality
               pruning: Hamerly bounds carried between iterations skip
               whole centroid tiles that provably cannot change any
               assignment (``supports_bounds=True``; extended 7-tuple with
               the new bounds state and the pruned-tile fraction).
               Bit-identical to ``lloyd`` by construction.
  lloyd_pruned_xla XLA analogue at finer granularity (row chunks x
               16-centroid groups, ``lax.cond`` per cell so skipped groups
               cost nothing off-TPU) — the non-TPU fast path and the
               pruned benchmark rung.

Every implementation is published through the ``repro.api`` backend
registry as an :class:`~repro.api.registry.AssignmentBackend` declaring its
capabilities (``supports_ft`` / ``takes_params`` / ``takes_injection``);
drivers obtain one via ``repro.api.get_backend(name)`` or let a
``FaultPolicy`` resolve it, and call it with the uniform
``backend(x, c, *, params=None, inj=None)`` signature.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import checksum
from repro.core.ft_gemm import ft_matmul
from repro.kernels import ops, ref


def _zero():
    return jnp.zeros((), jnp.int32)


@jax.jit
def assign_naive(x: jax.Array, c: jax.Array):
    # One "thread" per sample; centroids broadcast — no GEMM, pure VPU.
    # Batched over samples in chunks to bound the (M, K, F) intermediate.
    def per_sample(xi):
        d = jnp.sum((xi[None, :] - c) ** 2, axis=1)
        return jnp.argmin(d).astype(jnp.int32), jnp.min(d)
    am, md = jax.lax.map(per_sample, x, batch_size=1024)
    return am, md, _zero()


@jax.jit
def assign_gemm(x: jax.Array, c: jax.Array):
    # Materialize D, then reduce in a second pass. optimization_barrier
    # models the paper's separate-kernel round trip (prevents XLA from
    # fusing the argmin into the GEMM loop).
    d = ref.distance_matrix(x, c)
    d = jax.lax.optimization_barrier(d)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1), _zero()


@jax.jit
def assign_gemm_fused(x: jax.Array, c: jax.Array):
    d = ref.distance_matrix(x, c)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1), _zero()


def _row_norms(x) -> jax.Array:
    """True-distance correction term; reuses the DataPlan's precomputed
    norms instead of re-norming X every iteration. Always f32, like the
    plan's norms — bf16/fp16 X must not degrade the distance offsets. The
    QuantPlan's norms are the *unquantized* rows' (exact), matching the
    int8 template's exact-norm contract."""
    if isinstance(x, (ops.DataPlan, ops.QuantPlan)):
        return x.xn
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf, axis=1)


def assign_fused(x, c: jax.Array, params=None):
    am, md = ops.fused_assign(x, c, params)
    return am, md + _row_norms(x), _zero()


def assign_fused_ft(x, c: jax.Array, params=None,
                    inj: Optional[jax.Array] = None):
    am, md, det = ops.fused_assign_ft(x, c, params, inj=inj)
    return am, md + _row_norms(x), det


def assign_int8(x, c: jax.Array, params=None):
    # int8 distance template (one dtype notch past the paper's fp16
    # floor): per-row symmetric quantization of X and C, i8 x i8 -> i32
    # tile products, f32 scale correction + exact norm terms in the
    # epilogue. x may be a raw array or a prebuilt ops.QuantPlan (the
    # per-fit quantization); centroids are quantized per call (they move
    # every iteration).
    am, md = ops.fused_assign_int8(x, c, params)
    return am, md + _row_norms(x), _zero()


@jax.jit
def assign_int8_xla(x, c: jax.Array):
    # XLA analogue of the int8 template (non-TPU fast path): the same
    # per-row quantization and scale-corrected epilogue, with the i8 x i8
    # product carried in f32 — XLA's CPU int8 GEMM is several times slower
    # than f32, and the f32 carrier holds the identical integers for any
    # F <= 1040 (F * 127^2 < 2^24), so numerics match the kernel's int32
    # accumulator bit-for-bit on quantization-safe data.
    from repro.dist.compression import quantize_rows
    if isinstance(x, ops.QuantPlan):
        qx = x.xq[:x.m, :x.f].astype(jnp.float32)
        sx = x.sx[:x.m]
        xn = x.xn
    else:
        xf = x.astype(jnp.float32)
        q, sx = quantize_rows(xf)
        qx = q.astype(jnp.float32)
        xn = jnp.sum(xf * xf, axis=1)
    cf = c.astype(jnp.float32)
    qc, sc = quantize_rows(cf)
    cn = jnp.sum(cf * cf, axis=1)
    cross = jnp.matmul(qx, qc.astype(jnp.float32).T,
                       precision=jax.lax.Precision.HIGHEST,
                       preferred_element_type=jnp.float32)
    d = cn[None, :] - 2.0 * (sx * cross * sc.T)
    am = jnp.argmin(d, axis=1).astype(jnp.int32)
    return am, jnp.min(d, axis=1) + xn, _zero()


def assign_lloyd(x, c: jax.Array, params=None):
    # One-pass Lloyd (paper Fig. 4 shape): the Pallas kernel's epilogue
    # also accumulates per-cluster sums/counts, so the driver never
    # re-reads X for the centroid update. Extended 5-tuple contract.
    am, md, sums, counts = ops.fused_lloyd(x, c, params)
    return am, md, _zero(), sums, counts


def assign_lloyd_ft(x, c: jax.Array, params=None,
                    inj: Optional[jax.Array] = None):
    # One-pass FT Lloyd: the paper's §IV dual-checksum ABFT fused around
    # the distance GEMM *and* checksum protection of the one-hot update
    # epilogue (verified + recomputed in the jitted tree-reduction) — the
    # Fig. 6 scheme composed with the fused-update iteration.
    am, md, sums, counts, det = ops.fused_lloyd_ft(x, c, params, inj=inj)
    return am, md, det, sums, counts


@jax.jit
def assign_lloyd_xla(x: jax.Array, c: jax.Array):
    # XLA analogue of the one-pass kernel: assignment and the one-hot
    # update GEMM in a single fused graph (the non-TPU fast path; also the
    # benchmark ladder's one-pass rung).
    d = ref.distance_matrix(x, c)
    am = jnp.argmin(d, axis=1).astype(jnp.int32)
    md = jnp.min(d, axis=1)
    onehot = jax.nn.one_hot(am, c.shape[0], dtype=x.dtype)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
    return am, md, _zero(), sums, counts


@jax.jit
def assign_lloyd_ft_xla(x: jax.Array, c: jax.Array):
    # XLA analogue of the one-pass FT kernel (non-TPU fast path): the
    # distance cross product carries the paper's minimal dual *column*
    # checksum pair — e1/e2 over rows detect a single SEU, locate it
    # (column from the residual position, row from the e2/e1 ratio) and
    # correct it in place; the one-hot update is verified against
    # input-side e1/e2 encodings with a recompute-on-mismatch
    # fail-continue fix. Column-only verification halves the memory
    # passes of the full ft_matmul (this path exists to be the *fast*
    # host analogue); the in-kernel SEU descriptor surface is Pallas-only.
    k, m = c.shape[0], x.shape[0]
    xf = x.astype(jnp.float32)
    cf32 = c.astype(jnp.float32)
    cross = jnp.matmul(x, c.T, precision=jax.lax.Precision.HIGHEST,
                       preferred_element_type=jnp.float32)
    e1x = jnp.sum(xf, axis=0)                                # (F,)
    w_m = jnp.arange(1.0, m + 1.0, dtype=jnp.float32)
    e2x = w_m @ xf                                           # (F,)
    exp_c1 = e1x @ cf32.T                                    # (K,)
    exp_c2 = e2x @ cf32.T
    res_c1 = jnp.sum(cross, axis=0) - exp_c1
    res_c2 = w_m @ cross - exp_c2
    # clean-side scale (see the kernels: a corrupted-side scale would
    # self-mask large deltas); the column sums run over M rows, hence the
    # M-length contraction in the factor
    dscale = jnp.maximum(jnp.max(jnp.abs(exp_c1)), 1.0)
    dthr = checksum.threshold_factor(m * x.shape[1], x.dtype) * dscale
    j = jnp.argmax(jnp.abs(res_c1)).astype(jnp.int32)
    delta = res_c1[j]
    det_d = jnp.abs(delta) > dthr
    safe = jnp.where(delta == 0.0, 1.0, delta)
    i = jnp.clip((jnp.round(res_c2[j] / safe) - 1.0).astype(jnp.int32),
                 0, m - 1)
    fixed = cross.at[i, j].add(-delta)
    cross = jnp.where(det_d, fixed, cross)
    d = (jnp.sum(xf ** 2, axis=1, keepdims=True)
         + jnp.sum(cf32 ** 2, axis=1)[None, :] - 2.0 * cross)
    am = jnp.argmin(d, axis=1).astype(jnp.int32)
    md = jnp.min(d, axis=1)

    def update(x, am):
        onehot = jax.nn.one_hot(am, k, dtype=x.dtype)
        sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
        return sums, counts

    sums, counts = update(x, am)
    # epilogue checksums: e1^T (onehot^T X) = colsum(X) (= e1x, already
    # encoded above) and e2^T (onehot^T X) = (am+1)^T X — computed from
    # the inputs, never from the one-hot product they verify; each pair
    # thresholds against its own clean-side magnitude
    amw = (am + 1).astype(jnp.float32)
    exp2 = amw @ xf
    w_k = jnp.arange(1.0, k + 1.0, dtype=jnp.float32)
    factor = checksum.threshold_factor(m, x.dtype)
    thr1 = factor * jnp.maximum(jnp.max(jnp.abs(e1x)), 1.0)
    thr2 = factor * jnp.maximum(jnp.max(jnp.abs(exp2)), 1.0)
    cexp2 = jnp.sum(amw)
    bad = (jnp.any(jnp.abs(jnp.sum(sums, axis=0) - e1x) > thr1)
           | jnp.any(jnp.abs(w_k @ sums - exp2) > thr2)
           | (jnp.abs(jnp.sum(counts) - m) > factor * m)
           | (jnp.abs(w_k @ counts - cexp2)
              > factor * jnp.maximum(cexp2, 1.0)))

    def recompute(_):
        return update(jax.lax.optimization_barrier(x),
                      jax.lax.optimization_barrier(am))

    sums, counts = jax.lax.cond(bad, recompute,
                                lambda _: (sums, counts), operand=None)
    return (am, md, det_d.astype(jnp.int32) + bad.astype(jnp.int32),
            sums, counts)


def assign_lloyd_pruned(x, c: jax.Array, params=None, *, bounds=None):
    # Pruned one-pass Lloyd: the Pallas kernel skips whole (row tile,
    # centroid tile) cells whose decayed group lower bound cannot beat the
    # row tile's upper bound. Extended 7-tuple contract — the new bounds
    # state threads into the next iteration, the prune fraction into the
    # fit history.
    am, md, sums, counts, new_bounds, frac = ops.fused_lloyd_pruned(
        x, c, params, bounds=bounds)
    return am, md, _zero(), sums, counts, new_bounds, frac


# Granularity of the XLA pruned analogue: row chunks x centroid groups.
# Groups are much finer than a 128-wide MXU tile because XLA's skip
# mechanism (lax.cond) pays no lane-alignment cost — finer groups prune
# more, which is the whole point off-TPU.
_PRUNE_ROWS = 2048
_PRUNE_GROUP = 16


def _pruned_xla_grid(m: int, k: int) -> tuple[int, int, int, int]:
    """(row tile, num row tiles, group size, num groups) for (m, k)."""
    rt = min(_PRUNE_ROWS, m)
    g = min(_PRUNE_GROUP, k)
    return rt, -(-m // rt), g, -(-k // g)


def init_bounds_xla(m: int, k: int, f: int, params=None, *,
                    dtype=jnp.float32) -> ops.BoundsState:
    """Fresh bounds state shaped for the XLA pruned analogue's grid
    (``params`` and ``dtype`` are accepted for signature uniformity with
    :func:`ops.init_bounds` but the XLA grid does not depend on them)."""
    del params, dtype
    rt, nmt, g, kg = _pruned_xla_grid(m, k)
    return ops.BoundsState(
        ub=jnp.zeros((m,), jnp.float32),
        assign=jnp.zeros((m,), jnp.int32),
        tmin=jnp.zeros((nmt, kg), jnp.float32),
        c_prev=jnp.zeros((kg * g, f), jnp.float32),
        fresh=jnp.ones((), bool),
    )


@jax.jit
def assign_lloyd_pruned_xla(x: jax.Array, c: jax.Array, *, bounds=None):
    # XLA analogue of the pruned one-pass kernel: the distance work runs
    # per (row chunk, centroid group) cell under a lax.cond, so a skipped
    # cell costs nothing on CPU/GPU. The min fold over groups is exact
    # (strict compare, earlier group wins ties — the same first-index
    # tie-break as a whole-matrix argmin) and the one-hot update is the
    # verbatim assign_lloyd_xla update, so a run with pruning disabled is
    # bit-identical to this backend with bounds reset every call.
    m, f = x.shape
    k = c.shape[0]
    rt, nmt, g, kg = _pruned_xla_grid(m, k)
    mp, kp = nmt * rt, kg * g
    if bounds is None:
        bounds = init_bounds_xla(m, k, f)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    cp = jnp.pad(c, ((0, kp - k), (0, 0)))
    xf = xp.astype(jnp.float32)
    cf = cp.astype(jnp.float32)
    xn = jnp.sum(xf * xf, axis=1, keepdims=True)                 # (mp, 1)
    cn = jnp.where(jnp.arange(kp) < k,
                   jnp.sum(cf * cf, axis=1), jnp.inf)            # (kp,)
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    # Skip decision — the same decayed-bound test as ops.fused_lloyd_pruned
    drift = jnp.sqrt(jnp.sum((cf - bounds.c_prev) ** 2, axis=1))   # (kp,)
    gdrift = jnp.max(drift.reshape(kg, g), axis=1)                 # (kg,)
    ub_adj = bounds.ub + drift[bounds.assign]
    maxub = jnp.max(
        jnp.pad(ub_adj, (0, mp - m), constant_values=-jnp.inf)
        .reshape(nmt, rt), axis=1)                                 # (nmt,)
    tlb = bounds.tmin - gdrift[None, :]                            # (nmt, kg)
    if kg == 1:
        skip = jnp.zeros((nmt, kg), bool)
    else:
        can = tlb > maxub[:, None] * (1.0 + ops.PRUNE_SLACK) + ops.PRUNE_SLACK
        skip = jnp.logical_and(can, jnp.logical_not(bounds.fresh))
    ams, mds, tmins = [], [], []
    for i in range(nmt):
        xt = xp[i * rt:(i + 1) * rt]
        xnt = xn[i * rt:(i + 1) * rt]
        valid = (jnp.arange(rt) + i * rt) < m
        md_t = jnp.full((rt,), big, jnp.float32)
        am_t = jnp.zeros((rt,), jnp.int32)
        tmin_t = []
        for j in range(kg):
            cg = cp[j * g:(j + 1) * g]
            cng = cn[j * g:(j + 1) * g]

            def _compute(op, cg=cg, cng=cng, xt=xt, xnt=xnt, valid=valid,
                         base=j * g):
                md_t, am_t = op
                cross = jnp.matmul(xt, cg.T,
                                   precision=jax.lax.Precision.HIGHEST,
                                   preferred_element_type=jnp.float32)
                dcell = xnt + cng[None, :] - 2.0 * cross         # (rt, g)
                gmin = jnp.min(dcell, axis=1)
                garg = jnp.argmin(dcell, axis=1).astype(jnp.int32) + base
                take = gmin < md_t
                tmin_ij = jnp.min(jnp.where(
                    valid, jnp.sqrt(jnp.maximum(gmin, 0.0)), big))
                return (jnp.where(take, gmin, md_t),
                        jnp.where(take, garg, am_t), tmin_ij)

            def _skipped(op):
                md_t, am_t = op
                return md_t, am_t, big

            md_t, am_t, tmin_ij = jax.lax.cond(
                skip[i, j], _skipped, _compute, (md_t, am_t))
            tmin_t.append(tmin_ij)
        ams.append(am_t)
        mds.append(md_t)
        tmins.append(jnp.stack(tmin_t))
    am = jnp.concatenate(ams)[:m]
    md = jnp.concatenate(mds)[:m]
    tmin_k = jnp.stack(tmins)                                    # (nmt, kg)
    # the verbatim assign_lloyd_xla one-hot update (same accumulation
    # order, so final centroids cannot drift from the unpruned backend)
    onehot = jax.nn.one_hot(am, k, dtype=x.dtype)
    sums = jax.lax.dot_general(onehot, x, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=0)
    new_bounds = ops.BoundsState(
        ub=jnp.sqrt(jnp.maximum(md, 0.0)),
        assign=am,
        tmin=jnp.where(skip, tlb, tmin_k),
        c_prev=cf,
        fresh=jnp.zeros((), bool),
    )
    frac = jnp.mean(skip.astype(jnp.float32))
    return am, md, _zero(), sums, counts, new_bounds, frac


def assign_lloyd_batched(x, c: jax.Array, params=None):
    # Batched one-pass Lloyd: B independent problems through one kernel
    # launch, the problem axis mapped to the outermost grid dimension
    # (smallk epilogue per problem — batched problems have small K by
    # construction). Extended 5-tuple contract with a leading B axis.
    am, md, sums, counts = ops.fused_lloyd_batched(x, c, params)
    return am, md, _zero(), sums, counts


@jax.jit
def assign_lloyd_batched_xla(x: jax.Array, c: jax.Array):
    # XLA analogue of the batched one-pass kernel (non-TPU fast path): the
    # per-problem distance GEMM, argmin and one-hot update run as batched
    # contractions over the stacked (B, N, F) / (B, K, F) operands — XLA
    # loops the problem axis outside each GEMM, so per-problem numerics
    # match the B=1 call bit-for-bit while one dispatch covers all B.
    k = c.shape[1]
    xf = x.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    cross = jnp.matmul(x, jnp.swapaxes(c, 1, 2),
                       precision=jax.lax.Precision.HIGHEST,
                       preferred_element_type=jnp.float32)       # (B, N, K)
    d = (jnp.sum(xf * xf, axis=2, keepdims=True)
         + jnp.sum(cf * cf, axis=2)[:, None, :] - 2.0 * cross)
    am = jnp.argmin(d, axis=2).astype(jnp.int32)                 # (B, N)
    md = jnp.min(d, axis=2)
    onehot = jax.nn.one_hot(am, k, dtype=x.dtype)                # (B, N, K)
    sums = jax.lax.dot_general(
        onehot, x, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                      # (B, K, F)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=1)         # (B, K)
    return am, md, _zero(), sums, counts


@jax.jit
def assign_abft_offline(x: jax.Array, c: jax.Array):
    cross, detected = ft_matmul(x, c.T)
    d = (jnp.sum(x * x, axis=1, keepdims=True)
         + jnp.sum(c * c, axis=1)[None, :] - 2.0 * cross)
    return (jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1),
            detected.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Registry publication: the ladder as capability-declaring backends.
# ---------------------------------------------------------------------------

from repro.api.registry import AssignmentBackend, register_backend

register_backend(AssignmentBackend(
    "naive", assign_naive,
    doc="paper's basic implementation: per-sample scalar loop, no GEMM"))
register_backend(AssignmentBackend(
    "gemm", assign_gemm,
    doc="paper V1: GEMM + materialized D + separate argmin pass"))
register_backend(AssignmentBackend(
    "gemm_fused", assign_gemm_fused,
    doc="paper V2/V3 analogue: XLA fuses the GEMM epilogue (cuML baseline)"))
register_backend(AssignmentBackend(
    "fused", assign_fused, takes_params=True,
    doc="paper V4/V5: Pallas fused kernel (MXU + in-VMEM argmin)"))
register_backend(AssignmentBackend(
    "fused_ft", assign_fused_ft, supports_ft=True, takes_params=True,
    takes_injection=True,
    doc="paper §IV: fused kernel + dual-checksum online ABFT correction"))
register_backend(AssignmentBackend(
    "abft_offline", assign_abft_offline, supports_ft=True,
    doc="Wu-et-al-style baseline: checksummed GEMM, offline verification"))
register_backend(AssignmentBackend(
    "int8", assign_int8, takes_params=True, supports_int8=True,
    doc="int8 distance template: per-row quantized X/C, i8xi8->i32 MXU "
        "tiles, f32 scale-corrected epilogue with exact norm terms "
        "(bit-exact argmin on quantization-safe data)"))
register_backend(AssignmentBackend(
    "int8_xla", assign_int8_xla, supports_int8=True,
    doc="XLA analogue of the int8 template: same quantization and "
        "epilogue, f32-carrier GEMM over the quantized integers (non-TPU "
        "fast path)"))
register_backend(AssignmentBackend(
    "lloyd", assign_lloyd, takes_params=True, fuses_update=True,
    doc="one-pass Lloyd Pallas kernel: fused assignment + in-epilogue "
        "centroid accumulation (X read once per iteration)"))
register_backend(AssignmentBackend(
    "lloyd_xla", assign_lloyd_xla, fuses_update=True,
    doc="XLA analogue of the one-pass kernel (non-TPU fast path)"))
register_backend(AssignmentBackend(
    "lloyd_ft", assign_lloyd_ft, supports_ft=True, takes_params=True,
    takes_injection=True, fuses_update=True,
    doc="one-pass FT Lloyd Pallas kernel: fused dual-checksum ABFT on the "
        "distance GEMM + checksum-protected update epilogue (paper Fig. 6 "
        "composed with the fused-update iteration)"))
register_backend(AssignmentBackend(
    "lloyd_ft_xla", assign_lloyd_ft_xla, supports_ft=True, fuses_update=True,
    doc="XLA analogue of the one-pass FT backend (checksummed cross "
        "product + verified one-hot update; non-TPU fast path)"))
register_backend(AssignmentBackend(
    "lloyd_batched", assign_lloyd_batched, takes_params=True,
    fuses_update=True, supports_batch=True,
    doc="batched one-pass Lloyd Pallas kernel: B independent problems per "
        "launch, problem axis outermost in the grid (smallk epilogue per "
        "problem)"))
register_backend(AssignmentBackend(
    "lloyd_batched_xla", assign_lloyd_batched_xla, fuses_update=True,
    supports_batch=True,
    doc="XLA analogue of the batched one-pass kernel (batched contractions "
        "over the problem stack; non-TPU fast path)"))
register_backend(AssignmentBackend(
    "lloyd_pruned", assign_lloyd_pruned, takes_params=True,
    fuses_update=True, supports_bounds=True, bounds_init=ops.init_bounds,
    doc="pruned one-pass Lloyd Pallas kernel: Hamerly bounds skip whole "
        "centroid tiles that provably lose (bit-identical to lloyd; "
        "extended 7-tuple with bounds state + prune fraction)"))
register_backend(AssignmentBackend(
    "lloyd_pruned_xla", assign_lloyd_pruned_xla, fuses_update=True,
    supports_bounds=True, bounds_init=init_bounds_xla,
    doc="XLA analogue of the pruned one-pass backend (row-chunk x "
        "16-centroid-group cells under lax.cond; non-TPU fast path)"))
