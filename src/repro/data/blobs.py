"""Synthetic clustering data (isotropic Gaussian blobs), shardable.

The generator is deterministic in (seed, shard) so every host materializes
only its own shard — the pattern a 1000-node ingest uses (no global array
ever exists on one host).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def make_blobs(m: int, f: int, k: int, *, seed: int = 0, spread: float = 1.0,
               center_scale: float = 10.0, shard: int = 0, num_shards: int = 1,
               dtype=np.float32):
    """Returns (x (m_local, f), true_labels (m_local,)) for this shard."""
    assert m % num_shards == 0
    m_local = m // num_shards
    rng_centers = np.random.default_rng(seed)           # shared across shards
    centers = rng_centers.normal(size=(k, f)) * center_scale
    rng = np.random.default_rng(seed * 1_000_003 + shard + 1)
    labels = rng.integers(0, k, size=m_local)
    x = centers[labels] + rng.normal(size=(m_local, f)) * spread
    return jnp.asarray(x, dtype), jnp.asarray(labels, jnp.int32)
