from repro.data.blobs import make_blobs
from repro.data.synthetic import TokenPipeline

__all__ = ["make_blobs", "TokenPipeline"]
