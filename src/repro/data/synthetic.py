"""Synthetic token pipeline for LM training/serving.

Deterministic per (seed, step, shard): each data-parallel host generates its
own slice of the global batch, so the pipeline scales to any mesh without a
central reader. Mirrors a production loader's contract: ``next_batch(step)``
returns {tokens, labels} already shaped for the model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards

    def next_batch(self, step: int) -> dict[str, jax.Array]:
        rng = np.random.default_rng(
            (self.seed, step, self.shard))
        toks = rng.integers(
            0, self.vocab_size,
            size=(self.local_batch, self.seq_len + 1), dtype=np.int64)
        # Mix in structure so the loss actually decreases: repeat motifs.
        pos = np.arange(self.seq_len + 1)[None, :]
        motif = (pos * 31 + (step % 7)) % min(self.vocab_size, 997)
        mask = rng.uniform(size=toks.shape) < 0.7
        toks = np.where(mask, motif, toks)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
